"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table_methods,...]

Prints ``name,us_per_call,derived`` CSV. The first run trains the small
benchmark model (~1500 steps, cached under results/bench_model.npz).
Set REPRO_BENCH_TRAIN_STEPS to shrink for CI.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_kernels, fig_alpha, fig_window, paper_config,
                        roofline, table_ablation, table_genlength,
                        table_methods, table_prefill, table_trailing)

SUITES = {
    "table_methods": table_methods.main,      # paper Tables 1/2/8
    "table_ablation": table_ablation.main,    # paper Table 3
    "table_prefill": table_prefill.main,      # paper Table 4
    "table_genlength": table_genlength.main,  # paper Tables 5/13
    "table_trailing": table_trailing.main,    # paper Table 6
    "fig_window": fig_window.main,            # paper Figure 5
    "fig_alpha": fig_alpha.main,              # paper Figure 6
    "paper_config": paper_config.main,        # LLaDA-8B analytic flops
    "bench_kernels": bench_kernels.main,
    "roofline": roofline.main,                # §Roofline from dry-run
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    picked = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in picked:
        t0 = time.perf_counter()
        try:
            SUITES[name]()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
