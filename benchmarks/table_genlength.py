"""Paper Table 5/13 analogue: speedup grows with generation length —
the suffix-pruning advantage compounds as the suffix gets longer (the
paper reaches 225x at 2048). We sweep 32/64/128/256 on the tiny model
and report the NFE- and query-token-based speedup factors, plus the
analytic attended-token ratio at the paper's exact config (gen 512,
block 32, w=96) for the full-size backbones."""
from __future__ import annotations

from benchmarks.common import bench_model, emit, eval_prompts, run_method
from repro.core.suffix import suffix_query_region


def analytic_query_tokens(gen_len, block, window):
    """Sum of per-block query lengths (one refresh + steps amortized
    out): the structural compute ratio of Suf. pruning."""
    full = pruned = 0
    for c in range(gen_len // block):
        r_full = suffix_query_region(gen_start=0, gen_len=gen_len,
                                     block_size=block, block_idx=c, window=-1)
        r_p = suffix_query_region(gen_start=0, gen_len=gen_len,
                                  block_size=block, block_idx=c, window=window)
        full += r_full.query_len
        pruned += r_p.query_len
    return full / pruned


def main(n_eval: int = 24):
    cfg, params = bench_model()
    tok, samples, prompts = eval_prompts(cfg, n=n_eval)
    for gen_len in (16, 32, 64, 128):
        base = None
        for m in ("fast", "streaming"):
            r = run_method(cfg, params, prompts, samples, tok, method=m,
                           gen_len=gen_len, window=16)
            if base is None:
                base = r["qtok"]
            emit(f"table_genlength/gen{gen_len}/{m}",
                 1e6 * r["wall"] / max(r["result"].tokens_generated, 1),
                 f"acc={r['acc']:.3f};tps={r['tps']:.1f};nfe={r['nfe']};"
                 f"qtok_reduction={base/max(r['qtok'],1):.2f}x")
    # paper-config analytic ratios (gen 512/1024/2048, block 32, w=96)
    for g in (512, 1024, 2048):
        emit(f"table_genlength/analytic_gen{g}", 0.0,
             f"suffix_compute_ratio={analytic_query_tokens(g, 32, 96):.2f}x")


if __name__ == "__main__":
    main()
