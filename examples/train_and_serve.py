"""End-to-end driver: train a ~100M diffusion LM for a few hundred
steps, checkpoint it, then serve batched requests with the
Streaming-dLLM engine and report the methods table.

    PYTHONPATH=src python examples/train_and_serve.py \
        [--arch tiny-100m] [--steps 300] [--batch 16]

(The default arch is the 100M config; pass --arch tiny for a fast run.)
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.decoder import DecodeConfig, DiffusionDecoder
from repro.data.synthetic import ArithmeticDataset, exact_match
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config
from repro.training.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=64)
    ap.add_argument("--ckpt", default="results/train_and_serve")
    args = ap.parse_args()

    cfg = get_config(args.arch, block_size=8)
    print(f"== phase 1: train {cfg.name} "
          f"({cfg.param_count()/1e6:.0f}M params) for {args.steps} steps")
    params, hist = train(cfg, TrainConfig(
        steps=args.steps, batch_size=args.batch, seq_len=76,
        log_every=max(args.steps // 6, 1), checkpoint_path=args.ckpt))
    print(f"final loss {hist[-1]['loss']:.3f} "
          f"masked_acc {hist[-1]['masked_acc']:.3f}")

    print("\n== phase 2: serve batched requests")
    tok = ByteTokenizer(cfg.vocab_size)
    ds = ArithmeticDataset(tok, seq_len=76)
    samples = ds.eval_set(32)
    prompts = np.stack([tok.encode(s.prompt) for s in samples]).astype(np.int32)

    base_tps = None
    print(f"{'method':<12}{'acc':>6}{'NFE':>6}{'tok/s':>9}{'speedup':>9}")
    for method in ("vanilla", "dkv", "prefix", "fast", "streaming"):
        d = DecodeConfig(method=method, gen_len=args.gen_len, block_size=8,
                         window=16, tau0=0.9, alpha=0.3)
        dec = DiffusionDecoder(cfg, params, d)
        dec.generate(prompts[:1].copy())  # compile
        r = dec.generate(prompts.copy())
        acc = exact_match(tok, r.tokens, samples)
        tps = r.tokens_generated / r.wall_time
        if base_tps is None:
            base_tps = tps
        print(f"{method:<12}{acc:>6.2f}{r.nfe:>6}{tps:>9.1f}"
              f"{tps/base_tps:>8.1f}x")


if __name__ == "__main__":
    main()
