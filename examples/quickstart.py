"""Quickstart: train a small diffusion LM on arithmetic for a couple of
minutes, then decode the same prompts with Fast-dLLM and Streaming-dLLM
and watch the step counts drop.

    PYTHONPATH=src python examples/quickstart.py [--steps 800]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.decoder import DecodeConfig, DiffusionDecoder
from repro.data.synthetic import ArithmeticDataset, exact_match
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config
from repro.training.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=800)
    args = ap.parse_args()

    cfg = get_config("tiny", block_size=8)
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) ...")
    params, _ = train(cfg, TrainConfig(steps=args.steps, batch_size=32,
                                       seq_len=44, log_every=200))

    tok = ByteTokenizer(cfg.vocab_size)
    ds = ArithmeticDataset(tok, seq_len=44)
    samples = ds.eval_set(16)
    prompts = np.stack([tok.encode(s.prompt) for s in samples]).astype(np.int32)

    print(f"\n{'method':<12}{'acc':>6}{'NFE':>6}{'tok/s':>9}  steps/block")
    for method in ("vanilla", "fast", "streaming"):
        d = DecodeConfig(method=method, gen_len=32, block_size=8, window=8)
        r = DiffusionDecoder(cfg, params, d).generate(prompts.copy())
        acc = exact_match(tok, r.tokens, samples)
        tps = r.tokens_generated / r.wall_time
        print(f"{method:<12}{acc:>6.2f}{r.nfe:>6}{tps:>9.1f}  "
              f"{r.steps_per_block}")

    print("\nsample generations:")
    for i in range(4):
        print(f"  {samples[i].prompt!r} -> {tok.decode(r.tokens[i])!r} "
              f"(want {samples[i].answer})")


if __name__ == "__main__":
    main()
