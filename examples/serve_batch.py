"""Batched serving demo: a request queue served by the Streaming-dLLM
engine, compared against the Fast-dLLM configuration of the same engine.

    PYTHONPATH=src python examples/serve_batch.py [--n 48]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.decoder import DecodeConfig
from repro.core.engine import ServingEngine
from repro.data.synthetic import ArithmeticDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config
from repro.training.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--train-steps", type=int, default=600)
    args = ap.parse_args()

    cfg = get_config("tiny", block_size=8)
    params, _ = train(cfg, TrainConfig(steps=args.train_steps, batch_size=32,
                                       seq_len=44, log_every=200))
    tok = ByteTokenizer(cfg.vocab_size)
    ds = ArithmeticDataset(tok, seq_len=44)
    samples = ds.eval_set(args.n)

    for method in ("fast", "streaming"):
        d = DecodeConfig(method=method, gen_len=32, block_size=8, window=8)
        eng = ServingEngine(cfg, params, d, max_batch=16)
        for s in samples:
            eng.submit(s.prompt, max_tokens=32)
        done = eng.run_to_completion()
        hits = sum(int(c.text.strip() == s.answer)
                   for c, s in zip(sorted(done, key=lambda c: c.uid), samples))
        print(f"{method:<10} {len(done)} requests in "
              f"{eng.stats['batches']:.0f} batches, "
              f"{eng.throughput:.1f} tok/s, acc {hits/len(done):.2f}")


if __name__ == "__main__":
    main()
