"""Batched serving demo: one request queue served two ways — the legacy
synchronous engine (largest shape group decoded to completion) vs the
continuous block-level batcher (early-exit backfill, KV pool, streaming)
— for both the Fast-dLLM and Streaming-dLLM configurations.

    PYTHONPATH=src python examples/serve_batch.py [--n 48]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.decoder import DecodeConfig
from repro.core.engine import ServingEngine
from repro.data.synthetic import ArithmeticDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config
from repro.training.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--train-steps", type=int, default=600)
    args = ap.parse_args()

    cfg = get_config("tiny", block_size=8)
    params, _ = train(cfg, TrainConfig(steps=args.train_steps, batch_size=32,
                                       seq_len=44, log_every=200))
    tok = ByteTokenizer(cfg.vocab_size)
    ds = ArithmeticDataset(tok, seq_len=44)
    samples = ds.eval_set(args.n)
    # ragged generation budgets: early-exit-heavy rows free their slots
    budgets = [16 if i % 3 else 32 for i in range(args.n)]

    for method in ("fast", "streaming"):
        d = DecodeConfig(method=method, gen_len=32, block_size=8, window=8)
        for mode in ("batch", "continuous"):
            eng = ServingEngine(cfg, params, d, max_batch=16, mode=mode)
            for s, mt in zip(samples, budgets):
                eng.submit(s.prompt, max_tokens=mt)
            done = eng.run_to_completion()
            hits = sum(int(c.text.strip() == s.answer)
                       for c, s in zip(sorted(done, key=lambda c: c.uid),
                                       samples))
            extra = ""
            if mode == "continuous":
                snap = eng._continuous.metrics.snapshot()
                extra = (f", p50 {snap['latency_p50_s']*1e3:.0f}ms, "
                         f"occ {snap['mean_occupancy']:.2f}")
            print(f"{method:<10} {mode:<11} {len(done)} requests, "
                  f"{eng.throughput:.1f} tok/s, acc {hits/len(done):.2f}"
                  f"{extra}")


if __name__ == "__main__":
    main()
